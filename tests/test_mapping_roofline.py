"""Tests on the rCiM scheduler + roofline HLO parsing.

Deterministic scheduler/parsing tests always run; the hypothesis-driven
property tests are gated on the optional dependency
(``pip install -e .[test]``) instead of skipping the whole module.
"""

import numpy as np
import pytest

from repro.core.aig import AigStats
from repro.core.mapping import schedule_stats
from repro.core.sram import SramTopology

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False


def stats_from_levels(levels):
    ops = [dict(nand=a, nor=b, inv=c) for a, b, c in levels]
    return AigStats(
        n_pis=8, n_pos=4, n_ands=0, n_levels=len(ops), ops_per_level=ops,
        nand_count=sum(l[0] for l in levels),
        nor_count=sum(l[1] for l in levels),
        inv_count=sum(l[2] for l in levels),
    )


def test_capacity_monotone():
    stats = stats_from_levels([(400, 400, 200)] * 10)
    fits = [schedule_stats(stats, SramTopology(kb, 1)).fits for kb in (4, 8, 16, 32)]
    # once it fits, bigger macros also fit
    assert fits == sorted(fits)


def test_row_budget_gates_feasibility():
    """Regression: a wide-but-shallow netlist whose working set exceeds the
    row budget must NOT report fits=True on bit capacity alone.

    2000 NAND2 in one level on an 8-row x 1024-col macro (1 KB): the
    4-bits/gate rule passes (8000 <= 8192 bits) but the single level
    needs ceil(2000/512) = 4 batches -> 3*4+2 = 14 rows > 8.
    """
    from repro.core.batch import TopologyTable, WorkloadTable, schedule_batch
    from repro.core.mapping import BITS_PER_GATE

    starved = SramTopology.from_geometry(8, 1024, 1)
    wide = stats_from_levels([(2000, 0, 0)])
    deep = stats_from_levels([(64, 0, 0)] * 10)  # control: 5 rows suffice
    for disc in ("levels", "list"):
        res = schedule_stats(wide, starved, discipline=disc)
        assert BITS_PER_GATE * wide.total_gates <= starved.total_bits
        assert not res.fits, f"{disc}: row-starved schedule must not fit"
        assert res.rows_used <= starved.rows
        assert schedule_stats(deep, starved, discipline=disc).fits

    # The batched engine applies the identical two-term feasibility check.
    work = WorkloadTable.from_stats({("wide",): wide, ("deep",): deep})
    topos = TopologyTable.from_topologies([starved, SramTopology(8, 1)])
    for disc in ("levels", "list"):
        got = schedule_batch(work, topos, discipline=disc)
        for ti, topo in enumerate(topos.topologies):
            for ri, st_ in enumerate((wide, deep)):
                ref = schedule_stats(st_, topo, discipline=disc)
                assert bool(got["fits"][ti, ri]) == ref.fits


# ------------------------------ roofline parse ------------------------------

FAKE_HLO = """
ENTRY %main {
  %p0 = f32[256,1024]{1,0} parameter(0)
  %ag = f32[256,16384]{1,0} all-gather(%p0), replica_groups=[32,16]<=[512], dimensions={1}
  %ar = f32[256,1024]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = bf16[16,1024]{1,0} reduce-scatter(%something), replica_groups=[32,16]<=[512]
  %cp = f32[8,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = f32[64,512]{1,0} all-to-all(%p0), replica_groups=[32,16]<=[512]
  %ar2 = f32[4]{0} all-reduce-done(%ar)
}
"""


def test_collective_parse():
    from repro.launch.roofline import collective_bytes

    stats = collective_bytes(FAKE_HLO, default_group=16)
    kinds = set(stats.by_kind)
    assert kinds == {"all-gather", "all-reduce", "reduce-scatter",
                     "collective-permute", "all-to-all"}
    # all-gather: result 256*16384*4 bytes, n=16 -> 15/16 of result
    ag = 256 * 16384 * 4 * 15 / 16
    assert stats.by_kind["all-gather"] == pytest.approx(ag)
    # all-reduce: group list of 4 -> 2*(3/4)*payload
    ar = 2 * (3 / 4) * 256 * 1024 * 4
    assert stats.by_kind["all-reduce"] == pytest.approx(ar)
    # reduce-scatter: result is one shard -> (n-1)*result
    rs = 15 * 16 * 1024 * 2
    assert stats.by_kind["reduce-scatter"] == pytest.approx(rs)
    assert stats.by_kind["collective-permute"] == pytest.approx(8 * 128 * 4)
    assert stats.n_ops == 5  # -done line not double counted


TUPLE_HLO = """
ENTRY %main {
  %art = (f32[128,256]{1,0}, bf16[64]{0}) all-reduce(%a, %b), replica_groups=[4,8]<=[32], to_apply=%sum
  %agd = f32[32,2048]{1,0} all-gather(%p), channel_id=1, dimensions={1}
  %cp2 = bf16[4,64]{1,0} collective-permute(%p), source_target_pairs={{0,1},{1,2}}
}
"""


def test_collective_parse_tuple_and_default_group():
    from repro.launch.roofline import collective_bytes

    stats = collective_bytes(TUPLE_HLO, default_group=16)
    # variadic all-reduce: every tuple element is payload; n=8 from the
    # iota form [4,8]<=[32] (groups of size 8)
    payload = 128 * 256 * 4 + 64 * 2
    assert stats.by_kind["all-reduce"] == pytest.approx(2 * (7 / 8) * payload)
    # no replica_groups on the line -> the model-axis default group size
    ag = 32 * 2048 * 4
    assert stats.by_kind["all-gather"] == pytest.approx((15 / 16) * ag)
    # collective-permute is group-size independent: 1 x payload
    assert stats.by_kind["collective-permute"] == pytest.approx(4 * 64 * 2)
    assert stats.n_ops == 3


def test_collective_ring_factors_exact():
    """Pin each kind's ring factor on the shared fixture (the all-to-all
    term had no direct assertion before)."""
    from repro.launch.roofline import collective_bytes

    stats = collective_bytes(FAKE_HLO, default_group=16)
    assert stats.by_kind["all-to-all"] == pytest.approx(
        (15 / 16) * 64 * 512 * 4
    )
    # default_group must not leak into ops that carry explicit groups
    stats2 = collective_bytes(FAKE_HLO, default_group=4)
    assert stats2.by_kind["all-gather"] == stats.by_kind["all-gather"]
    assert stats2.by_kind["all-reduce"] == stats.by_kind["all-reduce"]


def test_group_size_fallbacks():
    from repro.launch.roofline import _group_size

    assert _group_size("replica_groups=[32,16]<=[512]", 8) == 16
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 8) == 4
    assert _group_size("channel_id=1, dimensions={1}", 8) == 8


def test_roofline_terms_and_bottleneck():
    from repro.launch.roofline import CollectiveStats, roofline_terms

    coll = CollectiveStats()
    coll.add("all-reduce", 50e9)  # exactly 1s of link time
    rl = roofline_terms(dict(flops=197e12 * 0.5, **{"bytes accessed": 819e9 * 0.25}),
                        coll, n_chips=256, model_flops_total=197e12 * 0.5 * 256 * 0.4)
    assert rl.compute_s == pytest.approx(0.5)
    assert rl.memory_s == pytest.approx(0.25)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.bottleneck == "collective"
    assert rl.useful_ratio == pytest.approx(0.4)


def test_model_flops_counting():
    from repro.launch.roofline import model_flops
    from repro.configs import get_config
    from repro.models.config import SHAPES

    dense = get_config("qwen1.5-4b")
    moe = get_config("deepseek-moe-16b")
    tr = SHAPES["train_4k"]
    assert model_flops(dense, tr) == 6.0 * dense.n_params() * tr.global_batch * tr.seq_len
    # MoE active < total
    assert moe.n_active_params() < moe.n_params()
    assert model_flops(moe, tr) == 6.0 * moe.n_active_params() * tr.global_batch * tr.seq_len


# ------------------------- property tests (hypothesis) ---------------------


if HAVE_HYPOTHESIS:

    level_strategy = st.lists(
        st.tuples(st.integers(0, 400), st.integers(0, 400), st.integers(0, 200)),
        min_size=1, max_size=30,
    ).filter(lambda ls: sum(sum(l) for l in ls) > 0)

    @settings(max_examples=40, deadline=None)
    @given(levels=level_strategy, kb=st.sampled_from([4, 8, 16, 32]),
           disc=st.sampled_from(["levels", "list"]))
    def test_schedule_invariants(levels, kb, disc):
        stats = stats_from_levels(levels)
        c1 = schedule_stats(stats, SramTopology(kb, 1), discipline=disc)
        c3 = schedule_stats(stats, SramTopology(kb, 3), discipline=disc)
        c6 = schedule_stats(stats, SramTopology(kb, 6), discipline=disc)
        # more concurrency never increases cycles
        assert c3.total_cycles <= c1.total_cycles
        assert c6.total_cycles <= c3.total_cycles
        # cycles at least cover the dependency depth
        assert c1.total_cycles >= stats.n_levels
        # op accounting is exact
        for c in (c1, c3, c6):
            assert sum(c.op_counts.values()) == stats.total_gates
            assert c.total_cycles > 0
            assert c.active_macro_cycles >= 0

    @settings(max_examples=30, deadline=None)
    @given(levels=level_strategy)
    def test_wider_macro_never_slower(levels):
        stats = stats_from_levels(levels)
        prev = None
        for kb in (4, 8, 16, 32):
            c = schedule_stats(stats, SramTopology(kb, 1), discipline="list")
            if prev is not None:
                assert c.total_cycles <= prev
            prev = c.total_cycles

else:  # pragma: no cover - CI installs the test extra

    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
    def test_property_scheduler():
        pass
