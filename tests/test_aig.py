"""AIG engine + benchmark-circuit functional correctness."""

import random

import numpy as np
import pytest

from repro.core import circuits as C
from repro.core.aig import Aig, random_aig


def bits_of(x, n):
    return [(x >> i) & 1 for i in range(n)]


def word_of(bits):
    return sum(b << i for i, b in enumerate(bits))


random.seed(1234)


def test_strash_dedup():
    aig = Aig(2)
    a, b = 2, 4  # literals of PI1, PI2
    x = aig.g_and(a, b)
    y = aig.g_and(b, a)
    assert x == y
    assert aig.n_ands == 1
    # constant folding
    assert aig.g_and(a, 0) == 0
    assert aig.g_and(a, 1) == a
    assert aig.g_and(a, a ^ 1) == 0


@pytest.mark.parametrize("n", [8, 16, 32])
def test_adder(n):
    a = C.gen_adder(n)
    for _ in range(20):
        x, y = random.getrandbits(n), random.getrandbits(n)
        out = a.eval_ints(bits_of(x, n) + bits_of(y, n))
        assert word_of(out[:n]) == (x + y) % (1 << n)
        assert out[n] == ((x + y) >> n) & 1


def test_multiplier():
    m = C.gen_multiplier(10)
    for _ in range(20):
        x, y = random.getrandbits(10), random.getrandbits(10)
        out = m.eval_ints(bits_of(x, 10) + bits_of(y, 10))
        assert word_of(out) == x * y


def test_square():
    m = C.gen_square(9)
    for _ in range(20):
        x = random.getrandbits(9)
        out = m.eval_ints(bits_of(x, 9))
        assert word_of(out) == x * x


def test_divisor():
    d = C.gen_divisor(10)
    for _ in range(30):
        x, y = random.getrandbits(10), random.getrandbits(10) or 1
        out = d.eval_ints(bits_of(x, 10) + bits_of(y, 10))
        assert word_of(out[:10]) == x // y
        assert word_of(out[10:]) == x % y


def test_sqrt():
    s = C.gen_sqrt(16)
    for _ in range(30):
        x = random.getrandbits(16)
        out = s.eval_ints(bits_of(x, 16))
        assert word_of(out) == int(x**0.5)


def test_max():
    m = C.gen_max(10, 4)
    for _ in range(20):
        ws = [random.getrandbits(10) for _ in range(4)]
        out = m.eval_ints([b for w in ws for b in bits_of(w, 10)])
        assert word_of(out) == max(ws)


def test_barrel():
    b = C.gen_barrel_shifter(32)
    for _ in range(20):
        d, sh = random.getrandbits(32), random.getrandbits(5)
        out = b.eval_ints(bits_of(d, 32) + bits_of(sh, 5))
        assert word_of(out) == d >> sh


def test_sine_accuracy():
    import math

    sn = C.gen_sine(10)
    errs = []
    for t in range(0, 1 << 10, 31):
        out = sn.eval_ints(bits_of(t, 10))
        v = word_of(out) / (1 << 10)
        errs.append(abs(v - math.sin(t / (1 << 10) * math.pi / 2)))
    assert max(errs) < 0.02


def test_gate_netlist_equivalence():
    rng = np.random.default_rng(0)
    for gen in [lambda: C.gen_adder(12), lambda: C.gen_multiplier(6),
                lambda: random_aig(10, 200, 6, seed=5)]:
        aig = gen()
        net = aig.to_gate_netlist()
        pv = rng.integers(0, 1 << 63, size=(aig.n_pis, 4), dtype=np.int64).astype(np.uint64)
        assert np.array_equal(aig.simulate(pv), net.simulate(pv))


def test_characterize_counts():
    aig = C.gen_adder(16)
    st = aig.characterize()
    assert st.total_gates == st.nand_count + st.nor_count + st.inv_count
    assert st.n_levels == len(st.ops_per_level)
    assert sum(sum(l.values()) for l in st.ops_per_level) == st.total_gates
    assert st.n_levels >= 4  # 16-bit adder needs real depth


def test_truth_table_small():
    aig = Aig(3)
    a, b, c = 2, 4, 6
    maj = aig.g_maj(a, b, c)
    aig.add_po(maj)
    tt = aig.truth_table(maj, [1, 2, 3])
    # majority truth table over 3 vars: 0xE8
    assert tt == 0xE8


def test_benchmark_suite_builds():
    suite = C.benchmark_suite(scale="tiny")
    assert set(suite) == {"adder", "bar", "mult", "sine", "max", "div", "sqrt",
                          "square", "log2"}
    for name, aig in suite.items():
        assert aig.n_ands > 0 and len(aig.pos) > 0, name
