#!/usr/bin/env python
"""Fail on broken intra-repo markdown links in README.md and docs/.

Checks every ``[text](target)`` whose target is not an external URL or a
pure in-page anchor: the referenced file must exist relative to the
linking file (anchors after ``#`` are stripped; they are not validated).

    python scripts/check_links.py            # from the repo root
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check(root: Path) -> list[str]:
    errors = []
    files = [root / "README.md"] + sorted((root / "docs").glob("**/*.md"))
    for md in files:
        if not md.exists():
            continue
        # strip fenced code blocks: their brackets are not links
        text = re.sub(r"```.*?```", "", md.read_text(), flags=re.S)
        for target in LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            if target.startswith("#"):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                errors.append(f"{md.relative_to(root)}: broken link -> {target}")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check(root)
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken intra-repo link(s)", file=sys.stderr)
        return 1
    print("docs links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
