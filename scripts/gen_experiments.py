"""Regenerate the data-driven sections of EXPERIMENTS.md from runs/.

    PYTHONPATH=src python scripts/gen_experiments.py
"""

from __future__ import annotations

import glob
import json
import os

HW = "TPU v5e: 197 TFLOP/s bf16/chip, 819 GB/s HBM, 50 GB/s/link ICI"


def load(out_dir="runs/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        if not r.get("tag"):
            recs.append(r)
    return recs


def fmt_s(x):
    return f"{x:.4f}" if x >= 1e-4 else (f"{x:.2e}" if x > 0 else "0")


def dryrun_section(recs) -> str:
    ok = [r for r in recs if "skipped" not in r]
    sk = [r for r in recs if "skipped" in r]
    lines = [
        "## §Dry-run",
        "",
        f"Every runnable (architecture x input-shape x mesh) cell lowers and "
        f"compiles with `jax.jit(step, in_shardings=...).lower().compile()` on "
        f"the production meshes — **{len(ok)} cells compiled, {len(sk)} "
        f"documented skips** (DESIGN.md §4).  Single pod = (16,16) "
        f"('data','model'), multi-pod = (2,16,16) ('pod','data','model') on "
        f"512 forced host devices.  Per-cell records (memory_analysis, "
        f"cost_analysis, collective schedule, trip-count-corrected roofline "
        f"terms) are in `runs/dryrun/*.json`.",
        "",
        "| arch | shape | mesh | HBM/dev (GB) | HLO flops/dev | HBM bytes/dev | link bytes/dev | collectives | compile (s) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in ok:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['hbm_per_device_gb']:.2f} | {rl['flops']:.2e} | "
            f"{rl['hbm_bytes']:.2e} | {rl['link_bytes']:.2e} | "
            f"{r.get('n_collectives', 0)} | {r['compile_s']:.0f} |"
        )
    lines.append("")
    lines.append("Skipped cells (see DESIGN.md §4):")
    for r in sk:
        lines.append(f"- {r['arch']} x {r['shape']} ({r['mesh']}): {r['skipped']}")
    lines.append("")
    return "\n".join(lines)


def roofline_section(recs) -> str:
    ok = [r for r in recs if "skipped" not in r and r["mesh"] == "single"]
    lines = [
        "## §Roofline",
        "",
        f"Hardware model: {HW}.  Terms per chip: compute = flops/197e12, "
        "memory = HBM bytes/819e9, collective = link bytes/50e9.  Flops / "
        "bytes / link-bytes come from the **trip-count-corrected HLO "
        "analysis** (DESIGN.md §7 — XLA's cost_analysis counts scan bodies "
        "once; raw XLA numbers are kept in each record).  MODEL_FLOPS = "
        "6*N*D (train) / 2*N_active*D (serve).  `useful` = MODEL_FLOPS / "
        "HLO flops — recompute (full remat), masked attention blocks and "
        "MoE capacity slack make it < 1; decode cells are tiny-compute by "
        "nature.  Single-pod (256-chip) table; multi-pod compiles are in "
        "§Dry-run.",
        "",
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | roofline frac | useful | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        "collective": "reduce cross-chip resharding (topology/DP-TP rebalance, bf16 collectives)",
        "memory": "cut HBM traffic (fuse, larger chunks, quantized KV/weights)",
        "compute": "raise MXU utilization (larger tiles, fewer masked blocks)",
    }
    for r in ok:
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / dom if dom else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"{rl['bottleneck']} | {frac:.3f} | {rl['useful_ratio']:.2f} | "
            f"{fixes[rl['bottleneck']]} |"
        )
    lines.append("")
    return "\n".join(lines)


def optimized_section() -> str:
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in load("runs/dryrun")
            if "skipped" not in r}
    opt = {(r["arch"], r["shape"], r["mesh"]): r for r in load("runs/dryrun_opt")
           if "skipped" not in r}
    if not opt:
        return ""
    lines = [
        "## §Optimized framework (before / after, single pod)",
        "",
        "Dominant roofline term per cell: baseline framework (`runs/dryrun`) "
        "vs optimized defaults (`runs/dryrun_opt`: hoisted attention gathers, "
        "flash-decode sharding rule, grouped MoE dispatch, checkpointed "
        "CE/attention scans).  Per-cell mesh-topology selection "
        "(core/mesh_explorer) adds further gains on top (§Perf).",
        "",
        "| arch | shape | dominant base (s) | dominant opt (s) | speedup | HBM base (GB) | HBM opt (GB) |",
        "|---|---|---|---|---|---|---|",
    ]
    gains = []
    for k in sorted(base):
        if k not in opt or k[2] != "single":
            continue
        rb, ro = base[k]["roofline"], opt[k]["roofline"]
        db = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        do = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        if do <= 0:
            continue
        gains.append(db / do)
        lines.append(
            f"| {k[0]} | {k[1]} | {fmt_s(db)} | {fmt_s(do)} | {db/do:.2f}x | "
            f"{base[k]['hbm_per_device_gb']:.2f} | {opt[k]['hbm_per_device_gb']:.2f} |"
        )
    if gains:
        import statistics

        lines.append("")
        lines.append(
            f"Geometric-mean speedup on the dominant term: "
            f"**{statistics.geometric_mean(gains):.2f}x** over {len(gains)} cells."
        )
    lines.append("")
    return "\n".join(lines)


def main():
    recs = load()
    out = [
        dryrun_section(recs),
        roofline_section(recs),
        optimized_section(),
    ]
    path = "EXPERIMENTS.generated.md"
    with open(path, "w") as f:
        f.write("\n".join(out))
    print(f"wrote {path} ({len(recs)} records)")


if __name__ == "__main__":
    main()
