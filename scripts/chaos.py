#!/usr/bin/env python
"""Chaos driver: the fault matrix over every named injection point.

Runs crash / hang / corrupt scenarios against each point in
`repro.runtime.faults.POINTS` with fixed seeds, asserting the
survivability contract after every one:

  * **recovery** — the layer under fault finishes (retry, rebuild,
    resume, degrade) instead of wedging or aborting the whole run;
  * **parity** — the surviving result is bit-identical to a clean
    reference (or, for quarantine scenarios, bit-identical on the
    surviving subset with the failure reported in a structured way);
  * **disabled means invisible** — with no plan armed, every injection
    point is a strict no-op and repeated runs are bit-identical.

In-process scenarios arm plans through `faults.injected`; scenarios
that hard-exit a process (``exit`` rules) arm through the
``REPRO_FAULTS`` environment of a spawned pool worker or a subprocess
sweep, with ``REPRO_FAULTS_ONCE_DIR`` bounding the global fire budget
so a retried task cannot re-fire forever.

    PYTHONPATH=src python scripts/chaos.py            # full matrix
    PYTHONPATH=src python scripts/chaos.py --list     # scenario names
    PYTHONPATH=src python scripts/chaos.py -k sweep   # substring filter

Exit status is the number of failed scenarios (0 = all recovered).
Invoked by ``scripts/ci.sh --chaos``.
"""

from __future__ import annotations

import argparse
import os
import shutil
import subprocess
import sys
import tempfile
import time
import traceback

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)

import numpy as np  # noqa: E402

from repro.core.circuits import benchmark_suite  # noqa: E402
from repro.core.sram import TOPOLOGY_LIBRARY  # noqa: E402
from repro.core.transforms import (  # noqa: E402
    CharacterizationCache,
    PoolPolicy,
    characterize_suite,
    resolve_backend,
)
from repro.core.sweep_runner import run_sweep  # noqa: E402
from repro.runtime import faults  # noqa: E402

CIRCUITS = ["adder", "bar", "max"]
RECIPES = [(), ("Rw",), ("Rf",), ("Ba", "Rw")]
TOPOS = list(TOPOLOGY_LIBRARY[:5])
SEED = 0
FAST = PoolPolicy(backoff_s=0.01, backoff_cap_s=0.1, seed=SEED)

_SCENARIOS: list = []


def scenario(point: str, action: str):
    def wrap(fn):
        fn.point, fn.action = point, action
        _SCENARIOS.append(fn)
        return fn

    return wrap


class Ctx:
    """Shared clean references + scratch space for every scenario."""

    def __init__(self, work: str):
        self.work = work
        self.circuits = benchmark_suite("tiny", only=CIRCUITS)
        self.cache = os.path.join(work, "cha")  # warm, for sweep scenarios
        self.cha_clean = characterize_suite(
            self.circuits, RECIPES, cache=self.cache, n_jobs=1,
            backend="python",
        )
        self.sweep_clean = run_sweep(
            self.circuits, journal_dir=None, shard_size=None,
            sram_list=TOPOS, recipes=RECIPES, cache=self.cache, n_jobs=1,
        )

    def tmp(self, name: str) -> str:
        path = os.path.join(self.work, name)
        os.makedirs(path, exist_ok=True)
        return path


def assert_cha_parity(got, ref, circuits=None):
    names = circuits if circuits is not None else sorted(ref)
    assert sorted(got) == sorted(names), (sorted(got), sorted(names))
    for c in names:
        assert got[c] == ref[c], f"characterization mismatch on {c}"


def assert_sweep_parity(out, ref, circuits=None):
    sel, rsel = out.selection, ref.selection
    rows = (
        slice(None)
        if circuits is None
        else [ref.circuits.index(c) for c in circuits]
    )
    assert np.array_equal(sel.winner_idx, rsel.winner_idx[rows])
    assert np.array_equal(
        sel.nominal_latency_ns, rsel.nominal_latency_ns[rows]
    )
    assert np.array_equal(sel.nominal_fits, rsel.nominal_fits[rows])
    for k, v in rsel.winner_metrics.items():
        assert np.array_equal(sel.winner_metrics[k], v[rows]), k


def _arm_env(once_dir: str, spec: str) -> dict:
    env = dict(os.environ)
    env["REPRO_FAULTS"] = spec
    env["REPRO_FAULTS_SEED"] = str(SEED)
    env["REPRO_FAULTS_ONCE_DIR"] = once_dir
    return env


class _env_armed:
    """Arm REPRO_FAULTS for spawned children; the parent stays disarmed
    (faults.disable pins the parent's env check)."""

    def __init__(self, once_dir: str, spec: str):
        self.spec, self.once = spec, once_dir

    def __enter__(self):
        os.environ["REPRO_FAULTS"] = self.spec
        os.environ["REPRO_FAULTS_SEED"] = str(SEED)
        os.environ["REPRO_FAULTS_ONCE_DIR"] = self.once
        faults.disable()

    def __exit__(self, *exc):
        for k in ("REPRO_FAULTS", "REPRO_FAULTS_SEED",
                  "REPRO_FAULTS_ONCE_DIR"):
            os.environ.pop(k, None)
        faults.disable()


# -- characterization pool (pool.task) --------------------------------------


@scenario("pool.task", "raise")
def pool_task_raise(ctx: Ctx):
    with _env_armed(ctx.tmp("once_pr"), "pool.task:raise::0:2"):
        out = characterize_suite(
            ctx.circuits, RECIPES, n_jobs=2, backend="python", policy=FAST
        )
    assert_cha_parity(out, ctx.cha_clean)


@scenario("pool.task", "exit")
def pool_task_exit(ctx: Ctx):
    # A worker hard-exits mid-task: BrokenProcessPool -> rebuild and
    # re-dispatch the in-flight work.
    with _env_armed(ctx.tmp("once_px"), "pool.task:exit::1:1"):
        out = characterize_suite(
            ctx.circuits, RECIPES, n_jobs=2, backend="python", policy=FAST
        )
    assert_cha_parity(out, ctx.cha_clean)


@scenario("pool.task", "hang")
def pool_task_hang(ctx: Ctx):
    # A worker sleeps past the per-task deadline: the attempt is failed,
    # the pool rebuilt (the stuck worker killed), and the task retried.
    # The deadline clock starts at submit and therefore absorbs
    # spawn-pool startup (~0.7s on this box with a jax-loaded parent),
    # so it must sit well above startup and well below the hang.
    policy = PoolPolicy(
        task_deadline_s=5.0, backoff_s=0.01, backoff_cap_s=0.1, seed=SEED
    )
    with _env_armed(ctx.tmp("once_ph"), "pool.task:hang::0:1:60"):
        out = characterize_suite(
            ctx.circuits, RECIPES, n_jobs=2, backend="python", policy=policy
        )
    assert_cha_parity(out, ctx.cha_clean)


# -- characterization front half (cha.backend) ------------------------------


@scenario("cha.backend", "raise")
def cha_backend_quarantine(ctx: Ctx):
    # A circuit whose characterization fails permanently is quarantined
    # with a structured failure; the rest of the sweep survives with
    # bit-identical rows.
    with faults.injected(
        faults.FaultRule("cha.backend", "raise", match=":bar", count=None),
        seed=SEED,
    ):
        out = run_sweep(
            ctx.circuits, journal_dir=None, shard_size=2, sram_list=TOPOS,
            recipes=RECIPES, cache=ctx.tmp("quarantine_cache"), n_jobs=1,
        )
    assert set(out.failures) == {"bar"}, out.failures
    assert out.circuits == tuple(c for c in CIRCUITS if c != "bar")
    assert_sweep_parity(out, ctx.sweep_clean, circuits=list(out.circuits))


@scenario("cha.backend", "raise")
def cha_backend_degrades_service(ctx: Ctx):
    # Device-backend failure inside the service descends the ladder to
    # the python parity path and flags the response degraded.
    if resolve_backend("auto") != "device":
        return "skipped: device backend unavailable"
    from repro.core.circuits import gen_adder
    from repro.serve.explore_service import (
        ExplorationService,
        ExploreRequest,
    )

    adder = gen_adder(6)
    with ExplorationService(sram_list=TOPOS, recipes=RECIPES,
                            start=False) as clean:
        ref = clean.explore(ExploreRequest(adder))
    assert ref.ok and not ref.degraded
    with ExplorationService(sram_list=TOPOS, recipes=RECIPES,
                            start=False) as svc:
        with faults.injected(
            faults.FaultRule("cha.backend", "raise", match="device"),
            seed=SEED,
        ):
            resp = svc.explore(ExploreRequest(adder))
    assert resp.ok and resp.degraded
    assert resp.winner.recipe == ref.winner.recipe
    assert resp.winner.topology == ref.winner.topology
    assert resp.winner.energy_nj == ref.winner.energy_nj


# -- characterization cache (cache.store) -----------------------------------


@scenario("cache.store", "corrupt")
def cache_store_corrupt(ctx: Ctx):
    # Every cache write is truncated mid-flight; reads must treat the
    # damage as a miss (never crash), and recharacterization restores
    # parity on a clean pass.
    cdir = ctx.tmp("corrupt_cache")
    with faults.injected(
        faults.FaultRule("cache.store", "corrupt", count=None), seed=SEED
    ):
        out = characterize_suite(
            ctx.circuits, RECIPES, cache=cdir, n_jobs=1, backend="python"
        )
        assert_cha_parity(out, ctx.cha_clean)  # in-memory result intact
    out2 = characterize_suite(
        ctx.circuits, RECIPES, cache=cdir, n_jobs=1, backend="python"
    )
    assert_cha_parity(out2, ctx.cha_clean)
    # The repaired cache round-trips warm.
    cache = CharacterizationCache(cdir)
    hits = sum(
        len(cache.load(aig.fingerprint()))
        for aig in ctx.circuits.values()
    )
    assert hits > 0, "no cache entries survived the clean rewrite"


# -- sweep runner (sweep.shard) ---------------------------------------------


@scenario("sweep.shard", "raise")
def sweep_shard_crash_resume(ctx: Ctx):
    journal = ctx.tmp("j_crash")
    try:
        with faults.injected(
            faults.FaultRule("sweep.shard", "raise", after=1), seed=SEED
        ):
            run_sweep(
                ctx.circuits, journal_dir=journal, shard_size=1,
                sram_list=TOPOS, recipes=RECIPES, cache=ctx.cache, n_jobs=1,
            )
        raise AssertionError("injected shard crash did not fire")
    except faults.FaultError:
        pass
    out = run_sweep(
        ctx.circuits, journal_dir=journal, shard_size=1, sram_list=TOPOS,
        recipes=RECIPES, cache=ctx.cache, n_jobs=1,
    )
    assert out.shards_resumed >= 1
    assert_sweep_parity(out, ctx.sweep_clean)


@scenario("sweep.shard", "exit")
def sweep_shard_kill_resume(ctx: Ctx):
    # The real thing: a subprocess sweep hard-exits mid-shard (the
    # kill -9 model) and a second invocation resumes from the journal.
    journal = ctx.tmp("j_kill")
    out_npz = os.path.join(ctx.work, "killed.npz")
    cmd = [
        sys.executable, "-m", "repro.core.sweep_runner",
        "--journal", journal, "--out", out_npz, "--shard-size", "1",
        "--cache", ctx.cache, "--circuits", ",".join(CIRCUITS),
        "--scale", "tiny", "--recipes", ";Rw;Rf;Ba,Rw", "--topos", "5",
    ]
    env = _arm_env(ctx.tmp("once_sk"), "sweep.shard:exit::1:1")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                      "src"), env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 42, (proc.returncode, proc.stderr[-2000:])
    assert not os.path.exists(out_npz), "crashed run must not publish out"
    env.pop("REPRO_FAULTS")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    got = np.load(out_npz)
    assert int(got["shards_resumed"]) >= 1
    ref = ctx.sweep_clean.selection
    assert np.array_equal(got["winner_idx"], ref.winner_idx)
    assert np.array_equal(got["nominal_latency_ns"], ref.nominal_latency_ns)


# -- shard journal (journal.write) ------------------------------------------


@scenario("journal.write", "raise")
def journal_write_failure_rerun(ctx: Ctx):
    # A publish that fails outright (disk error model): the sweep still
    # completes; the resume path treats the missing entry as absent work.
    journal = ctx.tmp("j_wfail")
    with faults.injected(
        faults.FaultRule("journal.write", "raise"), seed=SEED
    ):
        out = run_sweep(
            ctx.circuits, journal_dir=journal, shard_size=1,
            sram_list=TOPOS, recipes=RECIPES, cache=ctx.cache, n_jobs=1,
        )
        assert_sweep_parity(out, ctx.sweep_clean)
    out2 = run_sweep(
        ctx.circuits, journal_dir=journal, shard_size=1, sram_list=TOPOS,
        recipes=RECIPES, cache=ctx.cache, n_jobs=1,
    )
    assert out2.shards_run >= 1  # the unpublished shard was redone
    assert_sweep_parity(out2, ctx.sweep_clean)


@scenario("journal.write", "corrupt")
def journal_write_torn_frame(ctx: Ctx):
    # A torn append that survives the flush: the reader must skip the
    # damaged frame (crc + magic re-sync) and redo only that shard.
    journal = ctx.tmp("j_torn")
    with faults.injected(
        faults.FaultRule("journal.write", "corrupt"), seed=SEED
    ):
        run_sweep(
            ctx.circuits, journal_dir=journal, shard_size=1,
            sram_list=TOPOS, recipes=RECIPES, cache=ctx.cache, n_jobs=1,
        )
    out = run_sweep(
        ctx.circuits, journal_dir=journal, shard_size=1, sram_list=TOPOS,
        recipes=RECIPES, cache=ctx.cache, n_jobs=1,
    )
    assert 1 <= out.shards_run < len(CIRCUITS), out.shards_run
    assert_sweep_parity(out, ctx.sweep_clean)


# -- exploration service (service.process) ----------------------------------


@scenario("service.process", "raise")
def service_crash_survives(ctx: Ctx):
    from repro.core.circuits import gen_adder
    from repro.serve.explore_service import (
        ExplorationService,
        ExploreRequest,
    )

    adder = gen_adder(6)
    with ExplorationService(sram_list=TOPOS, recipes=RECIPES,
                            start=True) as svc:
        with faults.injected(
            faults.FaultRule("service.process", "raise"), seed=SEED
        ):
            resp = svc.submit(ExploreRequest(adder)).result(timeout=300)
        assert not resp.ok and resp.error.code == "worker-crashed"
        resp2 = svc.submit(ExploreRequest(adder)).result(timeout=300)
        assert resp2.ok, "worker did not survive the crashed batch"
        assert svc.stats()["worker_crashes"] == 1


@scenario("service.process", "hang")
def service_deadline_from_hang(ctx: Ctx):
    # A wedged pipeline burns a queued request's deadline; the service
    # resolves it with a structured deadline error instead of wedging,
    # then serves the next request normally.
    from repro.core.circuits import gen_adder
    from repro.serve.explore_service import (
        ExplorationService,
        ExploreRequest,
    )

    adder = gen_adder(6)
    with ExplorationService(sram_list=TOPOS, recipes=RECIPES,
                            start=False) as svc:
        fut = svc.submit(ExploreRequest(adder, deadline_s=0.3))
        with faults.injected(
            faults.FaultRule("service.process", "hang", hang_s=0.5),
            seed=SEED,
        ):
            time.sleep(0.4)  # the deadline expires while "wedged"
            svc.pump()
        resp = fut.result(timeout=5)
        assert not resp.ok and resp.error.code == "deadline-exceeded"
        resp2 = svc.explore(ExploreRequest(adder, deadline_s=600.0))
        assert resp2.ok


# -- disabled means invisible ------------------------------------------------


@scenario("(all)", "disabled")
def disabled_is_noop(ctx: Ctx):
    faults.disable()
    assert not faults.enabled()
    a = run_sweep(
        ctx.circuits, journal_dir=None, shard_size=2, sram_list=TOPOS,
        recipes=RECIPES, cache=ctx.cache, n_jobs=1,
    )
    b = run_sweep(
        ctx.circuits, journal_dir=None, shard_size=2, sram_list=TOPOS,
        recipes=RECIPES, cache=ctx.cache, n_jobs=1,
    )
    assert_sweep_parity(a, ctx.sweep_clean)
    assert_sweep_parity(b, ctx.sweep_clean)
    assert faults.corrupt("cache.store", b"payload") == b"payload"
    faults.inject("sweep.shard")  # must be a strict no-op


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-k", default="", help="substring filter on scenarios")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    chosen = [s for s in _SCENARIOS if args.k in s.__name__]
    if args.list:
        for s in chosen:
            print(f"{s.__name__}  [{s.point} x {s.action}]")
        return 0

    points = {s.point for s in chosen if s.point in faults.POINTS}
    if not args.k and points != set(faults.POINTS):
        print(f"matrix gap: uncovered points {set(faults.POINTS) - points}")
        return 1

    work = tempfile.mkdtemp(prefix="chaos_")
    failures = 0
    try:
        t0 = time.perf_counter()
        ctx = Ctx(work)
        print(f"references ready in {time.perf_counter() - t0:.1f}s "
              f"({len(chosen)} scenarios)")
        for s in chosen:
            faults.disable()
            t0 = time.perf_counter()
            try:
                note = s(ctx)
            except Exception:
                failures += 1
                print(f"FAIL {s.__name__} [{s.point} x {s.action}]")
                traceback.print_exc()
            else:
                dt = time.perf_counter() - t0
                tag = f" ({note})" if note else ""
                print(f"ok   {s.__name__} [{s.point} x {s.action}] "
                      f"{dt:.1f}s{tag}")
            finally:
                faults.disable()
        print(f"chaos matrix: {len(chosen) - failures}/{len(chosen)} "
              f"scenarios recovered with parity")
    finally:
        shutil.rmtree(work, ignore_errors=True)
    return failures


if __name__ == "__main__":
    sys.exit(main())
