#!/usr/bin/env bash
# CI entry point: tier-1 tests + explorer-backend benchmark in smoke mode.
#
#   scripts/ci.sh            # tests + smoke bench
#   scripts/ci.sh --no-bench # tests only
#
# Uses the PYTHONPATH=src layout (works without installation; `pip
# install -e .` works too, see pyproject.toml).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== explorer backend bench (smoke) =="
    python -m benchmarks.bench_explorer --smoke
    python - <<'EOF'
import json
with open("BENCH_explorer.json") as f:
    r = json.load(f)
total = r["total"]
assert total["all_agree"], "python/jax backends disagree on best implementation"
print(f"suite sweep speedup: {total['speedup']}x "
      f"(python {total['python_us']:.0f}us -> jax {total['jax_us']:.0f}us)")
EOF
fi
echo "CI OK"
