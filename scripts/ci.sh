#!/usr/bin/env bash
# CI entry point: tier-1 tests + docs link check + suite-level smoke bench.
#
#   scripts/ci.sh            # tests + docs check + smoke bench
#   scripts/ci.sh --no-bench # tests + docs check only
#
# Uses the PYTHONPATH=src layout (works without installation; `pip
# install -e .` works too, see pyproject.toml).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== docs link check =="
python scripts/check_links.py

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== suite-level explorer bench (smoke, cache cold + warm) =="
    mkdir -p runs
    python -m benchmarks.bench_explorer --smoke --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json
with open("runs/BENCH_explorer_smoke.json") as f:
    r = json.load(f)
total = r["total"]
assert total["all_agree"], "python/jax backends disagree on best implementation"
cold, warm = total["characterize_cold_s"], total["characterize_warm_s"]
assert warm < cold, f"warm cache not faster than cold ({warm}s vs {cold}s)"
assert warm < 2.0, f"warm-cache characterization should be near-zero, got {warm}s"
print(f"suite sweep speedup: {total['speedup']}x "
      f"(python {total['python_us']:.0f}us -> jax {total['jax_us']:.0f}us); "
      f"characterize cold {cold:.2f}s -> warm {warm:.3f}s; "
      f"e2e cold {total['e2e']['cold_s']}s / warm {total['e2e']['warm_s']}s")
EOF
fi
echo "CI OK"
