#!/usr/bin/env bash
# CI entry point: tier-1 tests + docs link check + suite-level smoke bench
# + model-variation smoke bench.
#
#   scripts/ci.sh            # full tests + docs check + smoke benches
#   scripts/ci.sh --no-bench # tests + docs check only
#   scripts/ci.sh --smoke    # fast profile: -m "not slow" marker split,
#                            # tighter per-test timeout, capped hypothesis
#   scripts/ci.sh --chaos    # also run the fault-injection matrix
#                            # (scripts/chaos.py) + the journal-overhead
#                            # gate (benchmarks.bench_faults, <2%);
#                            # combine with --no-bench for a focused
#                            # survivability run
#
# Uses the PYTHONPATH=src layout (works without installation; `pip
# install -e .` works too, see pyproject.toml).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
mkdir -p runs

RUN_BENCH=1
SMOKE=0
CHAOS=0
for arg in "$@"; do
    case "$arg" in
        --no-bench) RUN_BENCH=0 ;;
        --smoke)    SMOKE=1 ;;
        --chaos)    CHAOS=1 ;;
        *) echo "unknown flag: $arg (known: --no-bench --smoke --chaos)"; exit 2 ;;
    esac
done

# Per-test SIGALRM timeout (tests/conftest.py) so a hung test fails fast
# instead of stalling the pipeline, and a capped hypothesis "ci" profile
# so the property suites stay inside the CI time budget.
export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-ci}"
if [[ "$SMOKE" == 1 ]]; then
    export PYTEST_PER_TEST_TIMEOUT="${PYTEST_PER_TEST_TIMEOUT:-120}"
    export HYPOTHESIS_MAX_EXAMPLES="${HYPOTHESIS_MAX_EXAMPLES:-10}"
    PYTEST_MARKERS=(-m "not slow")
else
    export PYTEST_PER_TEST_TIMEOUT="${PYTEST_PER_TEST_TIMEOUT:-600}"
    PYTEST_MARKERS=()
fi

# The property suites (tests/test_transforms.py, test_variation.py, ...)
# need hypothesis (the pyproject `test` extra); install it when the
# environment doesn't ship it so those suites actually run in CI.  On
# air-gapped runners the install fails gracefully and the suites skip —
# the skip count below makes that visible instead of silent.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
    echo "== installing hypothesis (test extra) =="
    python -m pip install -q hypothesis \
        || echo "warning: could not install hypothesis (offline?); property suites will be SKIPPED"
fi

echo "== tier-1 tests (smoke=$SMOKE, per-test timeout ${PYTEST_PER_TEST_TIMEOUT}s) =="
python -m pytest -x -q -rs "${PYTEST_MARKERS[@]}" 2>&1 | tee runs/pytest.log
n_skipped=$(grep -Eo '[0-9]+ skipped' runs/pytest.log | tail -1 | grep -Eo '[0-9]+' || echo 0)
echo "skipped tests: ${n_skipped} (see runs/pytest.log for reasons)"

echo "== docs link check =="
python scripts/check_links.py

echo "== jit-discipline static analyzer (src tree + registered kernels) =="
# Fails (set -e) on any finding not in the checked-in baseline; the
# baseline is empty, so the tree must be *actually* clean.
python -m repro.analysis.lint --format json | tee runs/lint.json
python - <<'EOF'
import json
with open("runs/lint.json") as f:
    r = json.load(f)
c = r["counts"]
assert c["new"] == 0, f"{c['new']} new lint finding(s)"
print(f"lint: {c['new']} new, {c['baselined']} baselined, "
      f"{c['total']} total finding(s)")
EOF

echo "== static analyzer: every seeded-violation fixture must fail =="
# One fixture per rule (tests/lint_fixtures/); a rule that stops firing
# on its own seed is a dead rule, so each must exit non-zero.
for fx in tests/lint_fixtures/fx_ast_*.py; do
    if python -m repro.analysis.lint --no-jaxpr --baseline "" "$fx" >/dev/null 2>&1; then
        echo "FAIL: $fx passed the AST lint (seeded violation did not fire)"
        exit 1
    fi
done
for fx in tests/lint_fixtures/fx_jaxpr_*.py; do
    if python -m repro.analysis.lint --no-ast --baseline "" --kernels-from "$fx" >/dev/null 2>&1; then
        echo "FAIL: $fx passed the jaxpr lint (seeded violation did not fire)"
        exit 1
    fi
done
echo "all seeded fixtures correctly rejected"

echo "== static analyzer: guard flip checks on src/repro/core/batch.py =="
# The annotations must actually be guarding: strip one host-boundary
# annotation / one trace-counter increment from a *copy* of batch.py
# and the lint run must flip from green to failing.
lint_tmp=$(mktemp -d)
trap 'rm -rf "$lint_tmp"' EXIT
python - "$lint_tmp" <<'EOF'
import os, sys
src = open("src/repro/core/batch.py").read()
d = sys.argv[1]
ann = "  # repro: host-boundary\n"
assert ann in src, "no trailing host-boundary annotation to strip"
with open(os.path.join(d, "strip_annotation.py"), "w") as f:
    f.write(src.replace(ann, "\n", 1))
cnt = 'TRACE_COUNTS["schedule_grid"] += 1'
assert cnt in src, "no schedule_grid trace-counter increment to strip"
with open(os.path.join(d, "strip_counter.py"), "w") as f:
    f.write(src.replace(cnt, "", 1))
EOF
for f in strip_annotation strip_counter; do
    if python -m repro.analysis.lint --no-jaxpr --baseline "" "$lint_tmp/$f.py" >/dev/null 2>&1; then
        echo "FAIL: $f copy of batch.py still passes — the lint is not guarding"
        exit 1
    fi
done
rm -rf "$lint_tmp"
trap - EXIT
echo "both stripped copies correctly rejected"

# Style gate (pyproject [tool.ruff]); best-effort like the hypothesis
# install above: air-gapped runners without ruff warn and skip rather
# than fail — the jit-discipline lint above is the load-bearing gate.
if command -v ruff >/dev/null 2>&1; then
    echo "== ruff check =="
    ruff check src tests benchmarks scripts
else
    echo "warning: ruff not installed; style gate SKIPPED (pip install ruff to enable)"
fi

if [[ "$RUN_BENCH" == 1 ]]; then
    echo "== suite-level explorer bench (smoke, cache cold + warm) =="
    python -m benchmarks.bench_explorer --smoke --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json
with open("runs/BENCH_explorer_smoke.json") as f:
    r = json.load(f)
total = r["total"]
assert total["all_agree"], "python/jax backends disagree on best implementation"
cold, warm = total["characterize_cold_s"], total["characterize_warm_s"]
assert warm < cold, f"warm cache not faster than cold ({warm}s vs {cold}s)"
assert warm < 2.0, f"warm-cache characterization should be near-zero, got {warm}s"
print(f"suite sweep speedup: {total['speedup']}x "
      f"(python {total['python_us']:.0f}us -> jax {total['jax_us']:.0f}us); "
      f"characterize cold {cold:.2f}s -> warm {warm:.3f}s; "
      f"e2e cold {total['e2e']['cold_s']}s / warm {total['e2e']['warm_s']}s")
EOF

    echo "== characterization bench (smoke, device vs python front half) =="
    python -m benchmarks.bench_characterization --smoke \
        --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json
with open("runs/BENCH_explorer_smoke.json") as f:
    r = json.load(f)
assert "characterization" in r, \
    "bench must record a 'characterization' section"
cha = r["characterization"]
assert cha["backend_available"], \
    "device characterization backend unavailable (jax import failed)"
assert cha["parity"]["agree"], \
    "device and python characterization disagree (AigStats or transform " \
    "fingerprints differ on some (circuit, recipe))"
assert cha["parity"]["stats_checked"] > 0, "parity check did not run"
for t, pt in cha["per_transform"].items():
    assert pt["fingerprints_agree"], \
        f"transform {t}: device output fingerprint differs from python"
# The cold-start contract: once the persistent caches exist (XLA compile
# cache + CharacterizationCache), a fresh characterization run beats
# recomputing through the python-int path outright.
assert cha["device_warm_s"] < cha["python_cold_s"], \
    f"cache-warm device characterization ({cha['device_warm_s']}s) must " \
    f"beat the cold python path ({cha['python_cold_s']}s)"
assert cha["device_warm_s"] < cha["device_cold_s"], \
    "warm characterization cache not faster than cold"
print(f"characterization: python cold {cha['python_cold_s']}s, device "
      f"cold {cha['device_cold_s']}s / warm {cha['device_warm_s']}s; "
      f"parity on {cha['parity']['stats_checked']} (circuit, recipe) "
      f"stats + all transform fingerprints")
EOF

    echo "== model-variation sweep bench (smoke) =="
    python -m benchmarks.bench_variation --smoke --skip-pvt \
        --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json
with open("runs/BENCH_explorer_smoke.json") as f:
    v = json.load(f)["variation"]
assert v["all_agree"], \
    "backends disagree on a (circuit, variant) winner"
assert v["python_winners_checked"] > 0, "python cross-check did not run"
assert v["speedup"] > 1.0, \
    f"vmapped model sweep ({v['sweep_us']}us) must beat the serial " \
    f"per-model loop ({v['serial_us']}us)"
assert v["compiles"] == 1, \
    f"an N-variant sweep must cost exactly one jit trace, got {v['compiles']}"
assert v["recompiles_on_float_change"] == 0, \
    "changing only model floats retriggered tracing"
assert v["selection_agree"], \
    "batched selection disagrees with the per-(circuit, variant) " \
    "select_best loop"
assert v["selection_speedup"] > 1.0, \
    f"batched selection ({v['selection_batched_us']}us) must beat the " \
    f"per-variant loop ({v['selection_loop_us']}us)"
assert v["correlated_agree"], \
    "correlated (V, T) sweep: batched winners disagree with the loop"
assert v["correlated_compiles"] == 1, \
    f"a correlated (V, T) sweep must cost exactly one jit trace, " \
    f"got {v['correlated_compiles']}"
assert v["fused_agree"], \
    "fused on-device selection disagrees with host select_best_batch " \
    "on a (circuit, variant) winner"
assert v["fused_compiles"] == 1, \
    f"the fused evaluate+select sweep must cost exactly one jit " \
    f"trace, got {v['fused_compiles']}"
assert v["payload_fused_bytes"] < v["payload_host_bytes"], \
    f"fused device->host payload ({v['payload_fused_bytes']}B) must " \
    f"shrink vs the full-tensor transfer ({v['payload_host_bytes']}B)"
print(f"model sweep: {v['n_variants']} variants x "
      f"{v['implementations'] // v['n_variants']} designs in "
      f"{v['sweep_us']:.0f}us, serial {v['serial_us']:.0f}us "
      f"-> {v['speedup']}x, compiles={v['compiles']}; "
      f"selection {v['selection_loop_us']:.0f}us -> "
      f"{v['selection_batched_us']:.0f}us "
      f"({v['selection_speedup']}x); correlated sweep "
      f"compiles={v['correlated_compiles']}; fused pipeline "
      f"payload {v['payload_host_bytes']}B -> {v['payload_fused_bytes']}B "
      f"({v['payload_shrink']}x), {v['host_us']:.0f}us -> "
      f"{v['fused_us']:.0f}us, compiles={v['fused_compiles']}")
EOF
    echo "== system bench (smoke, rCiM vs conventional roofline per token) =="
    python -m benchmarks.bench_system --smoke \
        --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json, math
with open("runs/BENCH_explorer_smoke.json") as f:
    s = json.load(f)["system"]
assert len(s["configs"]) >= 4, \
    f"system bench must cover >= 4 configs, got {len(s['configs'])}"
for arch, ok in s["conservation"].items():
    assert ok, f"{arch}: lowered op stream not conserved (sum over " \
               f"levels != per-layer op totals)"
for arch, rec in s["configs"].items():
    assert rec["conserved"], f"{arch}: conservation flag false"
    for side in ("rcim", "baseline"):
        e = rec[side]["energy_per_token_j"]
        t = rec[side]["latency_per_token_s"]
        assert math.isfinite(e) and e > 0, f"{arch}/{side}: bad energy {e}"
        assert math.isfinite(t) and t > 0, f"{arch}/{side}: bad latency {t}"
sw = s["bw_sweep"]
assert sw["compiles"] == 1, \
    f"an N-point BW sweep must cost exactly one jit trace, got {sw['compiles']}"
assert sw["recompiles_on_value_change"] == 0, \
    "changing only bandwidth values retriggered tracing"
assert sw["memory_s_monotone"], "memory term not monotone in HBM BW"
print(f"system: {len(s['configs'])} configs compared "
      f"(conservation checked on {s['conservation_checked']}), "
      f"bw sweep {sw['n_points']} points, compiles={sw['compiles']}, "
      f"retraces={sw['recompiles_on_value_change']}")
EOF

    echo "== exploration service bench (smoke, warm persistent engine) =="
    python -m benchmarks.bench_service --smoke \
        --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json
with open("runs/BENCH_explorer_smoke.json") as f:
    s = json.load(f)["service"]
assert s["winners_agree"] == s["n_requests_total"], \
    f"only {s['winners_agree']}/{s['n_requests_total']} service winners " \
    f"match a fresh offline explore_request"
assert s["warm_p50_ms"] < s["cold_p50_ms"] / 10, \
    f"warm p50 ({s['warm_p50_ms']}ms) must be << cold p50 " \
    f"({s['cold_p50_ms']}ms)"
assert s["rerank_retrace"] == 0, \
    f"constraint-only re-ranks recompiled {s['rerank_retrace']} kernels"
assert s["fused_traces"] == s["distinct_buckets"], \
    f"{s['fused_traces']} fused jit traces for {s['distinct_buckets']} " \
    f"bucket shapes (must be exactly one per shape)"
print(f"service: cold p50 {s['cold_p50_ms']}ms -> warm p50 "
      f"{s['warm_p50_ms']}ms (p99 {s['warm_p99_ms']}ms), "
      f"{s['burst_rps']} rps, {s['fused_traces']} trace(s) for "
      f"{s['distinct_buckets']} bucket(s), "
      f"{s['winners_agree']}/{s['n_requests_total']} winners agree")
EOF
fi

if [[ "$CHAOS" == 1 ]]; then
    echo "== chaos matrix (fault injection over every registry point) =="
    # Exit status is the number of scenarios that failed to recover
    # with parity; the driver also fails on a registry point with no
    # scenario, so growing runtime/faults.py without covering the new
    # point here breaks CI.
    python scripts/chaos.py

    echo "== fault-tolerance bench (journal machinery gate) =="
    python -m benchmarks.bench_faults --n-iter 5 \
        --out runs/BENCH_explorer_smoke.json
    python - <<'EOF'
import json
with open("runs/BENCH_explorer_smoke.json") as f:
    r = json.load(f)["faults"]
# The ISSUE acceptance gate: shard journaling must add <2% to the warm
# full-suite sweep.  Gated on the serialized machinery upper bound
# (zero async-overlap credit), which is reproducible under ambient
# load where an end-to-end A/B of two ~80ms sweeps is not.
pct = r["machinery_overhead_pct"]
assert pct < 2.0, \
    f"journal machinery adds {pct:.2f}% to the warm sweep (gate: <2%)"
assert r["shards_resumed"] == r["crash_after_shards"], \
    "recovery run did not resume every journaled shard"
print(f"journal machinery: {r['publish_machinery_us']:.0f}us/publish x "
      f"{r['n_shards']} shards = {pct:.2f}% of the "
      f"{r['sweep_plain_ms']:.1f}ms warm sweep (gate <2%); "
      f"e2e A/B {r['journal_overhead_pct']:.2f}% (noisy, informational); "
      f"crash at shard {r['crash_after_shards']} recovered in "
      f"{r['recovery_ms']:.1f}ms")
EOF
fi
echo "CI OK"
