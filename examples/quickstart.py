"""Quickstart — the three layers of the system in one script.

    PYTHONPATH=src python examples/quickstart.py

1. Paper's tool: explore rCiM topologies for a combinational circuit.
2. CiM engine: execute the chosen netlist on the Pallas bit-plane kernel.
3. LM framework: train a tiny model for a few steps and generate from it.
"""

import jax
import jax.numpy as jnp
import numpy as np

# ---- 1. Algorithm I on a 16-bit adder -------------------------------------
from repro.core import circuits
from repro.core.explorer import explore

rtl = circuits.gen_adder(16)
result = explore(rtl, recipes=[("Ba",), ("Rw",), ("Rw", "Ba"), ("Rs", "Rw")])
print("== Algorithm I ==")
print(f"circuit: {result.circuit}  recipes tried: {result.n_recipes}")
print(f"best implementation: {result.table_row()}")

# ---- 2. Run the best AIG on the Pallas CiM engine --------------------------
from repro.core.transforms import RecipeRunner
from repro.kernels import ops

best_aig = RecipeRunner(rtl).run(result.best.recipe)
net = best_aig.to_gate_netlist()
x, y = 12345, 54321
bits = np.zeros((32, 1), np.uint8)
for i in range(16):
    bits[i, 0] = (x >> i) & 1
    bits[16 + i, 0] = (y >> i) & 1
out = ops.cim_evaluate(net, bits, block_words=128)
got = sum(int(out[i, 0]) << i for i in range(17))
print(f"\n== CiM engine ==\n{x} + {y} = {got} (expected {x+y})")
assert got == x + y

# ---- 3. Tiny LM: train a few steps, then sample ----------------------------
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.config import ParallelConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init, wsd_schedule
from repro.serve.engine import ServeEngine
from repro.train.steps import make_train_step

cfg = smoke_config("qwen1.5-4b")
model = Model(cfg, ParallelConfig(), q_chunk=16, kv_chunk=16)
params = model.init(jax.random.PRNGKey(0))
opt_cfg = AdamWConfig()
opt = adamw_init(params, opt_cfg)
data = Pipeline(DataConfig(batch_per_host=4, seq_len=64, vocab_size=cfg.vocab_size))
step = jax.jit(make_train_step(model, wsd_schedule(3e-3, 2, 6, 2), opt_cfg))
print("\n== LM training ==")
for s in range(8):
    batch = {k: jnp.asarray(v) for k, v in data.get_batch(s).items()}
    params, opt, m = step(params, opt, batch)
    print(f"step {s}: loss {float(m['loss']):.4f}")

engine = ServeEngine(model, params, batch=2, max_seq=64)
toks = engine.generate(np.ones((2, 16), np.int32), max_new=8)
print(f"generated tokens: {toks.tolist()}")
print("\nquickstart OK")
