"""Batched serving driver (deliverable b): slot-based continuous batching.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-27b \
        --preset smoke --batch 4 --requests 12 --prompt-len 24 --max-new 8
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "gemma3-27b", "--preset", "smoke",
                     "--batch", "2", "--requests", "4",
                     "--prompt-len", "24", "--max-new", "6"]
    main()
