"""Paper reproduction driver — Algorithm I over the EPFL-like suite.

    PYTHONPATH=src python examples/cim_explore.py --circuit adder --scale tiny
    PYTHONPATH=src python examples/cim_explore.py --all --scale default  # slower

    # persistent characterization cache: first run is cold, reruns are
    # near-instant (the sweep itself is one vmapped device call)
    PYTHONPATH=src python examples/cim_explore.py --all --cache runs/cha_cache

Prints the Table-I-style row for each circuit plus the best/worst spread.
"""

import argparse

from repro.core import circuits as C
from repro.core.explorer import best_worst, explore_suite


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuit", default="adder",
                    choices=list(C._GENERATORS) + ["all"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--scale", choices=["tiny", "default", "paper"], default="tiny")
    ap.add_argument("--max-latency-ns", type=float, default=None)
    ap.add_argument("--backend", choices=["python", "jax"], default="jax",
                    help="sweep backend: scalar reference or batched grid")
    ap.add_argument("--cache", default=None, metavar="DIR",
                    help="persistent characterization cache directory")
    ap.add_argument("--jobs", type=int, default=None,
                    help="characterization workers (default: min(4, cpus))")
    args = ap.parse_args()

    names = list(C._GENERATORS) if (args.all or args.circuit == "all") else [args.circuit]
    suite = C.benchmark_suite(scale=args.scale, only=names)
    results = explore_suite(
        suite, max_latency_ns=args.max_latency_ns, backend=args.backend,
        cache=args.cache, n_jobs=args.jobs,
    )
    for name, res in results.items():
        rtl = suite[name]
        b, w = best_worst(res)
        row = res.table_row()
        print(f"\n=== {name} ({rtl.n_ands} AIG nodes, {res.n_recipes} recipes, "
              f"{res.n_evaluations} implementations, {res.wall_s:.1f}s) ===")
        for k, v in row.items():
            print(f"  {k:14s} {v}")
        saving = 100 * (1 - b.metrics.energy_nj / w.metrics.energy_nj)
        print(f"  best-vs-worst energy saving: {saving:.1f}% "
              f"(paper avg 89.12%)")


if __name__ == "__main__":
    main()
