"""Paper reproduction driver — Algorithm I over the EPFL-like suite.

    PYTHONPATH=src python examples/cim_explore.py --circuit adder --scale tiny
    PYTHONPATH=src python examples/cim_explore.py --all --scale default  # slower

    # persistent characterization cache: first run is cold, reruns are
    # near-instant (the sweep itself is one vmapped device call)
    PYTHONPATH=src python examples/cim_explore.py --all --cache runs/cha_cache

    # energy-model variation: sweep process corners / Monte-Carlo samples
    # through the same single compile and report a yield summary
    PYTHONPATH=src python examples/cim_explore.py --all --model-sweep mc \
        --model-variants 32

Prints the Table-I-style row for each circuit plus the best/worst spread.
"""

import argparse

from repro.core import circuits as C
from repro.core.explorer import best_worst, explore_suite
from repro.core.sram import TOPOLOGY_LIBRARY, EnergyModel, ModelTable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuit", default="adder",
                    choices=list(C._GENERATORS) + ["all"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--scale", choices=["tiny", "default", "paper"], default="tiny")
    ap.add_argument("--max-latency-ns", type=float, default=None)
    ap.add_argument("--backend", choices=["python", "jax"], default="jax",
                    help="sweep backend: scalar reference or batched grid")
    ap.add_argument("--cache", default=None, metavar="DIR",
                    help="persistent characterization cache directory")
    ap.add_argument("--jobs", type=int, default=None,
                    help="characterization workers (default: min(4, cpus))")
    ap.add_argument("--model-sweep",
                    choices=["corners", "sensitivity", "mc", "correlated"],
                    default=None,
                    help="sweep EnergyModel variants (process corners, "
                         "one-at-a-time sensitivity, Monte-Carlo, or "
                         "correlated per-macro-geometry Monte-Carlo) through "
                         "the same compile and report a yield summary")
    ap.add_argument("--model-variants", type=int, default=16,
                    help="Monte-Carlo sample count "
                         "(--model-sweep mc/correlated)")
    ap.add_argument("--model-sigma", type=float, default=0.05,
                    help="relative sigma/spread for the model sweep")
    args = ap.parse_args()

    model_sweep = None
    if args.model_sweep == "corners":
        model_sweep = ModelTable.corners(EnergyModel(), spread=args.model_sigma)
    elif args.model_sweep == "sensitivity":
        model_sweep = ModelTable.sensitivity(EnergyModel(), rel=args.model_sigma)
    elif args.model_sweep == "mc":
        model_sweep = ModelTable.monte_carlo(
            EnergyModel(), n=args.model_variants, sigma=args.model_sigma, seed=0
        )
    elif args.model_sweep == "correlated":
        # topology-dependent (V, T) variation keyed on the library's
        # macro geometries — must match the swept topology list
        model_sweep = ModelTable.bitcell_sigma_per_macro(
            TOPOLOGY_LIBRARY, n=args.model_variants,
            sigma=args.model_sigma, seed=0,
        )

    names = list(C._GENERATORS) if (args.all or args.circuit == "all") else [args.circuit]
    suite = C.benchmark_suite(scale=args.scale, only=names)
    results = explore_suite(
        suite, max_latency_ns=args.max_latency_ns, backend=args.backend,
        cache=args.cache, n_jobs=args.jobs, model_sweep=model_sweep,
    )
    for name, res in results.items():
        rtl = suite[name]
        b, w = best_worst(res)
        row = res.table_row()
        print(f"\n=== {name} ({rtl.n_ands} AIG nodes, {res.n_recipes} recipes, "
              f"{res.n_evaluations} implementations, {res.wall_s:.1f}s) ===")
        for k, v in row.items():
            print(f"  {k:14s} {v}")
        saving = 100 * (1 - b.metrics.energy_nj / w.metrics.energy_nj)
        print(f"  best-vs-worst energy saving: {saving:.1f}% "
              f"(paper avg 89.12%)")
        if res.variation is not None:
            var = res.variation
            print(f"  model sweep ({var.n_variants} variants): "
                  f"best_yield={var.best_yield:.2f} "
                  f"latency_yield={var.latency_yield:.2f}")
            q = var.energy_quantiles
            print(f"  winner energy [nJ]: p5={q[0.05]:.4g} "
                  f"median={q[0.5]:.4g} p95={q[0.95]:.4g} "
                  f"cvar(0.9)={var.cvar(0.9):.4g}")
            for impl, share in sorted(var.winner_share.items(),
                                      key=lambda kv: -kv[1]):
                print(f"    {impl:32s} wins {share:.0%} of variants")


if __name__ == "__main__":
    main()
