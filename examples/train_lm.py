"""End-to-end training driver (deliverable b).

Train a ~100M-parameter model for a few hundred steps:

    PYTHONPATH=src python examples/train_lm.py --arch minicpm-2b \
        --preset 100m --steps 300 --batch 8 --seq 512 --ckpt-dir runs/ckpt_100m

CPU-quick variant (CI): --preset smoke --steps 20 --batch 2 --seq 64.
Resume after interruption with --resume.  All flags are forwarded to
repro.launch.train.
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "minicpm-2b", "--preset", "smoke",
                     "--steps", "10", "--batch", "2", "--seq", "64"]
    main()
